//! `fastpbrl` launcher: the single self-contained binary that drives every
//! training mode of the reproduction (python never runs at request time).
//!
//! Subcommands:
//!   list                       show available AOT artifacts
//!   train  [--pbt-interval N]  (PBT-)population training (TD3/SAC/DQN —
//!                              the domain is picked from the artifact)
//!   cemrl  ...                 CEM-RL with the shared critic (§5.2)
//!   dvd    ...                 DvD diversity training (§5.3)
//!   top    <run-dir|jsonl>     live per-member/per-phase telemetry table
//!   watchdog -- <train args>   supervise a trainer: restart on crash/stall,
//!                              resuming from the checkpoint lineage
//!   report ...                 plot results CSVs in the terminal

use fastpbrl::coordinator::cem::{run_cemrl, CemRlConfig};
use fastpbrl::coordinator::dvd::DvdLambdaSchedule;
use fastpbrl::coordinator::hyperparams::HyperSpec;
use fastpbrl::coordinator::pbt::{Explore, PbtController};
use fastpbrl::coordinator::trainer::{run_training, Controller, NoController, TrainerConfig};
use fastpbrl::manifest::Manifest;
use fastpbrl::util::cli::Cli;
use fastpbrl::util::config::Config;
use fastpbrl::util::log::info;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> anyhow::Result<()> {
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    match cmd {
        "list" => list(rest),
        "train" => train(rest),
        "cemrl" => cemrl(rest),
        "dvd" => dvd(rest),
        "report" => report(rest),
        "top" => top(rest),
        "watchdog" => watchdog(rest),
        _ => {
            println!(
                "fastpbrl — Fast Population-Based RL on a Single Machine (ICML 2022)\n\n\
                 Usage: fastpbrl <list|train|cemrl|dvd|top|watchdog|report> [options]\n\
                 Run a subcommand with --help for its options."
            );
            Ok(())
        }
    }
}

/// Live telemetry table: tail a run's JSONL snapshot stream (written
/// when training runs with `--telemetry`) and render it in place.
fn top(argv: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new(
        "fastpbrl top",
        "live per-member/per-phase view of a training run's telemetry stream",
    )
    .opt("refresh", "2", "seconds between redraws")
    .opt("iterations", "0", "redraw count before exiting (0 = until Ctrl-C)");
    let args = cli.parse(argv)?;
    let target = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or(fastpbrl::RESULTS_DIR);
    fastpbrl::telemetry::top::run_top(
        std::path::Path::new(target),
        args.get_f64("refresh")?,
        args.get_u64("iterations")?,
    )
}

/// The run dir the child trainer will use, derived from its
/// `--checkpoint` argument — the watchdog and the trainer must agree on
/// where `run.json`, the heartbeat, and the telemetry stream live.
fn checkpoint_run_dir(child_args: &[String]) -> Option<std::path::PathBuf> {
    let mut ckpt: Option<&str> = None;
    let mut i = 0;
    while i < child_args.len() {
        let a = &child_args[i];
        if let Some(v) = a.strip_prefix("--checkpoint=") {
            ckpt = Some(v);
        } else if a == "--checkpoint" {
            ckpt = child_args.get(i + 1).map(|s| s.as_str());
            i += 1;
        }
        i += 1;
    }
    let ckpt = ckpt.filter(|s| !s.is_empty())?;
    let p = std::path::Path::new(ckpt);
    Some(match p.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
        _ => std::path::PathBuf::from("."),
    })
}

/// Out-of-process supervision: spawn the trainer as a child, restart it
/// on crash or stall; each restart auto-resumes from the checkpoint
/// lineage's `last_good`.
fn watchdog(argv: &[String]) -> anyhow::Result<()> {
    use fastpbrl::runtime::watchdog::{run_watchdog, WatchdogConfig, WatchdogOutcome};
    let cli = Cli::new(
        "fastpbrl watchdog",
        "supervise a trainer: restart on crash or stall, resuming from the \
         checkpoint lineage\n\
         (usage: fastpbrl watchdog [opts] -- train --checkpoint <path> ...)",
    )
    .opt("max-process-restarts", "5", "restart budget before giving up")
    .opt("backoff-ms", "1000", "base restart backoff (doubles per restart)")
    .opt("backoff-cap-ms", "60000", "restart backoff cap")
    .opt(
        "heartbeat-timeout-secs",
        "120",
        "kill a child silent for this long (0 = watch exit status only)",
    )
    .opt(
        "crash-loop-window-secs",
        "10",
        "failures this soon after launch count toward the crash-loop streak",
    )
    .opt(
        "crash-loop-threshold",
        "3",
        "consecutive fast failures before giving up permanently (0 = off)",
    )
    .opt("poll-ms", "200", "child liveness poll interval");
    let sep = argv.iter().position(|a| a == "--");
    let (own, child) = match sep {
        Some(i) => (&argv[..i], &argv[i + 1..]),
        None => (&argv[..], &[][..]),
    };
    // `Cli::parse` reports --help as an error (exit code 1); the watchdog
    // is scripted (CI smokes it), so its --help must exit 0.
    if own.iter().any(|a| a == "--help" || a == "-h") {
        println!("{}", cli.usage());
        return Ok(());
    }
    anyhow::ensure!(
        !child.is_empty(),
        "watchdog needs a child command after `--`, e.g.:\n  \
         fastpbrl watchdog -- train --algo td3 --checkpoint runs/a/ckpt.bin"
    );
    let args = cli.parse(own)?;
    let run_dir = checkpoint_run_dir(child).ok_or_else(|| {
        anyhow::anyhow!(
            "the child command must carry --checkpoint <path>: restarts resume from \
             the lineage, and its parent dir hosts run.json and the heartbeat file"
        )
    })?;
    let cfg = WatchdogConfig {
        program: std::env::current_exe()?,
        args: child.to_vec(),
        run_dir,
        max_process_restarts: args.get_u32("max-process-restarts")?,
        backoff_base_ms: args.get_u64("backoff-ms")?,
        backoff_cap_ms: args.get_u64("backoff-cap-ms")?,
        heartbeat_timeout_secs: args.get_f64("heartbeat-timeout-secs")?,
        crash_loop_window_secs: args.get_f64("crash-loop-window-secs")?,
        crash_loop_threshold: args.get_u32("crash-loop-threshold")?,
        poll_ms: args.get_u64("poll-ms")?,
        ..WatchdogConfig::default()
    };
    let report = run_watchdog(&cfg)?;
    match report.outcome {
        WatchdogOutcome::Completed => Ok(()),
        WatchdogOutcome::BudgetExhausted => anyhow::bail!(
            "trainer kept failing after {} restart(s); last failure: {}",
            report.restarts,
            report.last_failure.unwrap_or_default()
        ),
        WatchdogOutcome::CrashLoop => anyhow::bail!(
            "crash loop — the trainer dies within seconds of every launch; \
             last failure: {}",
            report.last_failure.unwrap_or_default()
        ),
    }
}

/// Render results CSVs as terminal charts (Fig 5/6-style curves).
fn report(argv: &[String]) -> anyhow::Result<()> {
    use fastpbrl::util::plot::{ascii_chart, parse_csv, series};
    let cli = Cli::new("fastpbrl report", "plot results/*.csv in the terminal")
        .opt("x", "wall_s", "x column (wall_s | env_steps | updates)")
        .opt("y", "best_return", "y column")
        .opt("width", "72", "chart width")
        .opt("height", "16", "chart height");
    let args = cli.parse(argv)?;
    let files: Vec<String> = if args.positional.is_empty() {
        let mut v: Vec<String> = std::fs::read_dir("results")
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .map(|e| e.path().display().to_string())
                    .filter(|p| p.ends_with(".csv"))
                    .collect()
            })
            .unwrap_or_default();
        v.sort();
        v
    } else {
        args.positional.clone()
    };
    anyhow::ensure!(!files.is_empty(), "no CSV files found (run an example first)");
    for f in files {
        let Ok(text) = std::fs::read_to_string(&f) else { continue };
        let Ok((header, cols)) = parse_csv(&text) else { continue };
        let (x, y) = (args.get("x"), args.get("y"));
        if !header.iter().any(|h| h == x) || !header.iter().any(|h| h == y) {
            continue; // bench CSVs have different columns; skip silently
        }
        let s = series(&header, &cols, x, y)?;
        println!("\n== {f} ==");
        print!("{}", ascii_chart(&[(y, &s)],
                                  args.get_usize("width")?,
                                  args.get_usize("height")?, x, y));
    }
    Ok(())
}

fn list(argv: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new("fastpbrl list", "show available AOT artifacts")
        .opt("artifacts", "artifacts", "artifacts directory");
    let args = cli.parse(argv)?;
    let m = Manifest::load(args.get("artifacts"))?;
    println!("{:<44} {:>5} {:>3} {:>6} {:>10}", "artifact", "pop", "k", "batch", "state");
    for (name, a) in &m.artifacts {
        println!(
            "{:<44} {:>5} {:>3} {:>6} {:>10}",
            name, a.pop, a.num_steps, a.batch, a.state_size
        );
    }
    Ok(())
}

fn base_cli(name: &'static str, about: &'static str) -> Cli {
    Cli::new(name, about)
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("config", "", "optional config file (key = value)")
        .opt("env", "pendulum", "environment name")
        .opt("pop", "4", "population size")
        .opt("updates", "2000", "total update steps")
        .opt("seed", "0", "random seed")
        .opt("csv", "", "CSV metrics output path")
        .opt("checkpoint", "", "checkpoint file (saved at sync points; resumed when present)")
        .opt("keep-checkpoints", "3", "rotated checkpoint generations to keep")
        .opt("max-actor-restarts", "3", "respawn budget per crashed actor thread (0 = off)")
        .opt("stall-timeout-ms", "5000", "actor stall watchdog timeout (0 = off)")
        .opt("max-seconds", "0", "wall-clock budget (0 = unlimited)")
        .opt(
            "replay-shards",
            "1",
            "shared-replay ingest stripes (0 = one per actor thread; needs shared replay)",
        )
        .opt(
            "telemetry",
            "",
            "live telemetry: JSONL snapshot path or run dir (pair with `fastpbrl top`)",
        )
}

fn trainer_config_from(args: &fastpbrl::util::cli::Args, algo: &str)
                       -> anyhow::Result<TrainerConfig> {
    let mut cfg = TrainerConfig::new(algo, args.get("env"))
        .with_pop(args.get_usize("pop")?)
        .with_updates(args.get_u64("updates")?)
        .with_seed(args.get_u64("seed")?)
        .with_csv(args.get("csv"))
        .with_checkpoint(args.get("checkpoint"))
        .with_keep_checkpoints(args.get_usize("keep-checkpoints")?)
        .with_max_actor_restarts(args.get_u32("max-actor-restarts")?)
        .with_stall_timeout_ms(args.get_u64("stall-timeout-ms")?)
        .with_max_seconds(args.get_f64("max-seconds")?)
        .with_replay_shards(args.get_usize("replay-shards")?);
    let telemetry_path = args.get("telemetry");
    if !telemetry_path.is_empty() {
        cfg.telemetry = fastpbrl::telemetry::TelemetryConfig::jsonl(telemetry_path);
    }
    // optional config file refinements
    let path = args.get("config");
    if !path.is_empty() {
        let file = Config::load(path)?;
        cfg.sync_every = file.get_usize("train.sync_every", cfg.sync_every as usize)? as u64;
        cfg.warmup_steps = file.get_usize("train.warmup_steps", cfg.warmup_steps)?;
        cfg.replay_capacity = file.get_usize("train.replay_capacity", cfg.replay_capacity)?;
        cfg.replay_shards = file.get_usize("train.replay_shards", cfg.replay_shards)?;
        cfg.ratio = file.get_f64("train.ratio", cfg.ratio)?;
        cfg.n_actor_threads =
            file.get_usize("train.actor_threads", cfg.n_actor_threads)?;
        cfg.drain_bound =
            file.get_usize("train.drain_bound", cfg.drain_bound as usize)? as u64;
        cfg.actor_sleep_us =
            file.get_usize("train.actor_sleep_us", cfg.actor_sleep_us as usize)? as u64;
        cfg.expl_noise = file.get_f64("train.expl_noise", cfg.expl_noise as f64)? as f32;
        cfg.eps_greedy = file.get_f64("train.eps_greedy", cfg.eps_greedy as f64)? as f32;
        // supervision / fault-tolerance knobs
        cfg.keep_checkpoints =
            file.get_usize("train.keep_checkpoints", cfg.keep_checkpoints)?;
        cfg.max_actor_restarts =
            file.get_u64("train.max_actor_restarts", cfg.max_actor_restarts as u64)? as u32;
        cfg.restart_backoff_ms =
            file.get_u64("train.restart_backoff_ms", cfg.restart_backoff_ms)?;
        cfg.stall_timeout_ms =
            file.get_u64("train.stall_timeout_ms", cfg.stall_timeout_ms)?;
        cfg.health_norm_limit =
            file.get_f64("train.health_norm_limit", cfg.health_norm_limit)?;
        // runtime-recovery knobs (transient-fault retries + device-loss
        // rebuild budget; see runtime::classify_fault)
        cfg.runtime_retries =
            file.get_u64("train.runtime_retries", cfg.runtime_retries as u64)? as u32;
        cfg.runtime_retry_backoff_ms =
            file.get_u64("train.runtime_retry_backoff_ms", cfg.runtime_retry_backoff_ms)?;
        cfg.max_device_restarts =
            file.get_u64("train.max_device_restarts", cfg.max_device_restarts as u64)? as u32;
        // telemetry knobs (--telemetry sets the JSONL path; the file can
        // flip the switch alone, tune cadence, or add a Prometheus dump)
        cfg.telemetry.enabled =
            file.get_bool("telemetry.enabled", cfg.telemetry.enabled)?;
        cfg.telemetry.snapshot_secs =
            file.get_f64("telemetry.snapshot_secs", cfg.telemetry.snapshot_secs)?;
        if let Some(p) = file.get("telemetry.prometheus_path") {
            cfg.telemetry.prometheus_path = p.to_string();
        }
        // kernel-selection overrides for A/B runs (auto | reference |
        // tiled, auto | direct | im2col); absent keys keep Auto dispatch
        fastpbrl::nn::kernels::configure(
            file.get("kernels.matmat"),
            file.get("kernels.conv"),
        )?;
    }
    Ok(cfg)
}

fn train(argv: &[String]) -> anyhow::Result<()> {
    let cli = base_cli(
        "fastpbrl train",
        "population training (TD3/SAC/DQN — continuous and pixel artifacts \
         dispatch through the same loop), optional PBT",
    )
    .opt("algo", "td3", "td3 | sac | dqn")
    .opt("pbt-interval", "0", "PBT evolution interval in updates (0 = no PBT)")
    .opt("pbt-frac", "0.3", "PBT truncation fraction")
    .opt("explore", "resample", "PBT explore: resample | perturb");
    let args = cli.parse(argv)?;
    let manifest = Manifest::load(args.get("artifacts"))?;
    let algo = args.get("algo").to_string();
    let mut cfg = trainer_config_from(&args, &algo)?;
    let interval = args.get_u64("pbt-interval")?;
    let mut controller: Box<dyn Controller> = if interval > 0 {
        cfg.hyper_spec = Some(HyperSpec::for_algo(&algo)?);
        let explore = match args.get("explore") {
            "perturb" => Explore::Perturb,
            _ => Explore::Resample,
        };
        Box::new(PbtController::new(
            HyperSpec::for_algo(&algo)?,
            interval,
            args.get_f64("pbt-frac")?,
            explore,
        ))
    } else {
        Box::new(NoController)
    };
    info(&format!(
        "training {} pop={} env={} ({} updates)",
        algo, cfg.pop, cfg.env, cfg.total_updates
    ));
    let summary = run_training(&manifest, cfg, controller.as_mut())?;
    info(&format!(
        "done: {:.1}s wall, {} updates, {} env steps, best return {:.1}, mean {:.1}",
        summary.wall_seconds, summary.updates, summary.env_steps,
        summary.best_return, summary.mean_return
    ));
    if summary.actor_restarts > 0 || summary.stalled_actors > 0 || summary.members_repaired > 0
    {
        info(&format!(
            "supervision: {} actor restarts, {} stall events, {} members repaired",
            summary.actor_restarts, summary.stalled_actors, summary.members_repaired
        ));
    }
    if summary.runtime_retries > 0 || summary.device_restarts > 0 {
        info(&format!(
            "runtime recovery: {} transient retries, {} device restarts",
            summary.runtime_retries, summary.device_restarts
        ));
    }
    print!("{}", summary.timers.report());
    Ok(())
}

fn cemrl(argv: &[String]) -> anyhow::Result<()> {
    let cli = base_cli("fastpbrl cemrl", "CEM-RL with shared critic (§5.2)")
        .opt("ordering", "vec", "update ordering: vec (ours) | seq (original)")
        .opt("iters", "10", "CEM iterations")
        .opt("rounds", "10", "update rounds per iteration")
        .opt("steps-per-iter", "1000", "env steps collected per iteration");
    let args = cli.parse(argv)?;
    let manifest = Manifest::load(args.get("artifacts"))?;
    let cfg = CemRlConfig {
        env: args.get("env").to_string(),
        pop: args.get_usize("pop")?,
        iters: args.get_usize("iters")?,
        rounds_per_iter: args.get_usize("rounds")?,
        steps_per_iter: args.get_usize("steps-per-iter")?,
        seed: args.get_u64("seed")?,
        csv_path: args.get("csv").to_string(),
        max_seconds: args.get_f64("max-seconds")?,
        ordering: args.get("ordering").to_string(),
        ..CemRlConfig::default()
    };
    let summary = run_cemrl(&manifest, &cfg)?;
    info(&format!(
        "cemrl done: {:.1}s wall, {} updates, best {:.1}, mean {:.1}, mu {:.1}",
        summary.wall_seconds, summary.updates, summary.best_return,
        summary.mean_return, summary.mu_return
    ));
    print!("{}", summary.timers.report());
    Ok(())
}

fn dvd(argv: &[String]) -> anyhow::Result<()> {
    let cli = base_cli("fastpbrl dvd", "DvD diversity training (§5.3)");
    let args = cli.parse(argv)?;
    let manifest = Manifest::load(args.get("artifacts"))?;
    let mut cfg = trainer_config_from(&args, "dvd")?;
    cfg.shared_replay = true;
    let total = cfg.total_updates;
    let mut controller = DvdLambdaSchedule::default_for(total);
    info(&format!(
        "dvd training pop={} env={} ({} updates)",
        cfg.pop, cfg.env, total
    ));
    let summary = run_training(&manifest, cfg, &mut controller)?;
    info(&format!(
        "dvd done: {:.1}s wall, {} updates, best return {:.1}, mean {:.1}",
        summary.wall_seconds, summary.updates, summary.best_return, summary.mean_return
    ));
    Ok(())
}
